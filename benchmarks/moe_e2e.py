"""End-to-end MoE train-step benchmark: full fwd+bwd with irregular
communication on BOTH edges — dispatch via alltoallv, combine and the
gradient return via reduce_scatterv — all through ``PlannerService``
(the ROADMAP MoE throughput target).

Two legs, both device-free (the repo's synthetic-machine methodology,
see ``benchmarks/pipeline_bench.py``):

* **throughput study** — for (decode, prefill) x (uniform, single_hot,
  zipf) load shapes, model one fwd+bwd train step on a RAGGED batch
  (per-shard token counts follow the same load shape):

      t_step = t_dispatch + t_combine        (fwd comm)
             + t_grad_in + t_grad_out        (bwd comm)
             + t_compute + t_reorder

  Forward: dispatch alltoallv ``S``, expert matmul, combine via
  ``reduce_scatterv(n)`` — each expert's gated contributions flow back
  and are SUMMED en route (top-k combine is a sum, so the combine edge
  is a reduction, not a permutation).  Backward: ``allgatherv(n)``
  makes the output gradient visible to every expert, the summed input
  gradient returns via a second ``reduce_scatterv(n)``, and dW is
  local.  All four plans are SELECTED by a ``PlannerService`` and timed
  on a deterministic synthetic true machine; compute is 3x the forward
  einsum FLOPs (dX + dW matmuls) on the critical expert; reorder is 4
  pack/unpack HBM passes.  The BASELINE is the regular padded
  collectives: padded direct all-to-all, padded recursive-halving
  reduce-scatter, padded all-gather (what XLA emits on equal blocks),
  plus same-capacity compute.  The ROADMAP target is asserted in report
  form: **>= 90% of the padded baseline at uniform loads, winning at
  skewed loads**.

* **numeric end-to-end leg** — a small (p=8) ragged top-2-routed batch
  REALLY flows fwd+bwd through the selected plans in the NumPy oracles
  (``execute_alltoallv_plan_numpy``, ``execute_steps_numpy``,
  ``execute_reduce_scatterv_plan_numpy``): expert outputs are gated and
  summed by the combine reduce_scatterv, the backward pass gathers dy,
  returns dX through a reduce_scatterv, and computes dW locally.  The
  outputs y, the input gradients dX, and the weight gradients dW must
  all match the dense per-token reference — the fast path is not
  allowed to trade correctness for speed.

Writes ``results/moe_e2e.json`` (schema: EXPERIMENTS.md §MoE e2e):

    PYTHONPATH=src python benchmarks/moe_e2e.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):  # direct-script execution
    _REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_REPO, os.path.join(_REPO, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)
    from benchmarks.common import emit, ragged_moe_problem
else:
    from .common import emit, ragged_moe_problem

from repro.core.costmodel import CostParams
from repro.tuner import (Candidate, PlannerService, SyntheticTimingBackend,
                         plan_pipeline_cost, plan_step_cost)

RESULTS = os.path.join(os.environ.get("REPRO_RESULTS", os.getcwd()),
                       "results")

P = 16                       # experts == devices
D_MODEL = 2_048
D_FF = 8_192
ROW_BYTES = D_MODEL * 2      # bf16 activations
PEAK_FLOPS = 2.0e14          # per-device bf16 peak (flops/s)
HBM_BW = 8.0e11              # bytes/s for the pack/unpack reorder passes
FLOPS_PER_ROW = 3 * 2 * D_MODEL * D_FF   # wi, wg, wo einsums (forward)
UNIFORM_TARGET = 0.90        # ROADMAP: >= 90% of regular padded comm


def measure_plan(plan, machine: SyntheticTimingBackend,
                 row_bytes: int) -> float:
    """Seconds the true machine takes to run a lowered plan: wrap it as
    a Candidate priced under its own cost discipline (stage-synchronous
    when pipelined, per-step otherwise) and time it with
    ``SyntheticTimingBackend.measure`` — the same measurement path the
    tuner's races use, noise model included."""
    cost = plan_pipeline_cost if plan.segments > 1 else plan_step_cost
    cand = Candidate("plan", "alltoallv", True,
                     cost_fn=lambda P: cost(plan, P),
                     builder=lambda: plan)
    return machine.measure(cand, row_bytes=row_bytes)


def step_times(svc: PlannerService, machine: SyntheticTimingBackend,
               n: np.ndarray, S: np.ndarray) -> dict:
    """One fwd+bwd MoE step through the service-selected plans.

    Comm edges: dispatch ``alltoallv(S)``; combine ``reduce_scatterv(n)``
    (gated expert outputs summed per token); bwd ``allgatherv(n)`` of the
    output gradient + ``reduce_scatterv(n)`` returning the summed input
    gradient (dW needs no comm under expert parallelism)."""
    sizes = [int(v) for v in n]
    disp = svc.plan_record("alltoallv", S, row_bytes=ROW_BYTES)
    comb = svc.plan_record("reduce_scatterv", sizes, row_bytes=ROW_BYTES)
    agrad = svc.plan_record("allgatherv", sizes, row_bytes=ROW_BYTES)
    rows_critical = int(S.sum(axis=0).max())   # busiest expert's tokens
    total_rows = int(S.sum())
    t_dispatch = measure_plan(disp.plan, machine, ROW_BYTES)
    t_combine = measure_plan(comb.plan, machine, ROW_BYTES)
    t_grad_in = measure_plan(agrad.plan, machine, ROW_BYTES)
    t_grad_out = measure_plan(comb.plan, machine, ROW_BYTES)
    # fwd einsums + the two backward matmuls (dX, dW) on the critical
    # expert: 3x the forward FLOPs
    t_compute = 3 * rows_critical * FLOPS_PER_ROW / PEAK_FLOPS
    # pack/unpack HBM passes: fwd (pack dispatch, unpack combine) + bwd
    # (pack grads, unpack dX) over the critical device's rows
    t_reorder = 4 * rows_critical * ROW_BYTES / HBM_BW
    t_comm = t_dispatch + t_combine + t_grad_in + t_grad_out
    return {
        "dispatch_algo": disp.algo, "combine_algo": comb.algo,
        "grad_gather_algo": agrad.algo,
        "segments": disp.plan.segments,
        "padding_overhead": disp.plan.padding_overhead,
        "t_dispatch_s": t_dispatch, "t_combine_s": t_combine,
        "t_grad_in_s": t_grad_in, "t_grad_out_s": t_grad_out,
        "t_comm_s": t_comm,
        "t_compute_s": t_compute, "t_reorder_s": t_reorder,
        "t_step_s": t_comm + t_compute + t_reorder,
        "rows_critical": rows_critical, "total_rows": total_rows,
    }


def baseline_times(machine: SyntheticTimingBackend, n: np.ndarray,
                   S: np.ndarray) -> dict:
    """Regular padded collectives: every block inflated to the global
    max, lowered through the exact same machinery — monolithic direct
    pairwise all-to-all, recursive-halving reduce-scatter, tree
    all-gather (what XLA emits on equal blocks) — plus same-capacity
    expert compute."""
    from repro.core.composed import (alltoallv_direct_schedule,
                                     reduce_scatterv_halving_schedule)
    from repro.core.jax_collectives import (plan_allgatherv, plan_alltoallv,
                                            plan_reduce_scatterv)

    p = S.shape[0]
    pad = np.full((p, p), int(S.max()), np.int64)
    pad_n = [int(n.max())] * p
    a2a = plan_alltoallv(pad, validate=False,
                         schedule=alltoallv_direct_schedule(pad))
    rs = plan_reduce_scatterv(pad_n, validate=False,
                              schedule=reduce_scatterv_halving_schedule(
                                  pad_n))
    ag = plan_allgatherv(pad_n, validate=False)
    t_a2a = measure_plan(a2a, machine, ROW_BYTES)
    t_rs = measure_plan(rs, machine, ROW_BYTES)
    t_ag = measure_plan(ag, machine, ROW_BYTES)
    rows_cap = int(pad.sum(axis=0).max())     # p * max block
    t_compute = 3 * rows_cap * FLOPS_PER_ROW / PEAK_FLOPS
    t_reorder = 4 * rows_cap * ROW_BYTES / HBM_BW
    t_comm = t_a2a + 2 * t_rs + t_ag
    return {
        "t_dispatch_s": t_a2a, "t_combine_s": t_rs,
        "t_grad_in_s": t_ag, "t_grad_out_s": t_rs,
        "t_comm_s": t_comm,
        "t_compute_s": t_compute, "t_reorder_s": t_reorder,
        "t_step_s": t_comm + t_compute + t_reorder,
        "rows_critical": rows_cap,
    }


def throughput_study(svc: PlannerService, machine: SyntheticTimingBackend,
                     rows: list) -> list[dict]:
    out = []
    for regime, tokens in (("decode", 4_096), ("prefill", 65_536)):
        for shape in ("uniform", "single_hot", "zipf"):
            n, S = ragged_moe_problem(P, tokens, shape)
            fast = step_times(svc, machine, n, S)
            base = baseline_times(machine, n, S)
            tput = fast["total_rows"] / fast["t_step_s"]
            base_tput = fast["total_rows"] / base["t_step_s"]
            ratio = tput / base_tput
            rec = {
                "regime": f"{regime}_{shape}", "tokens": tokens,
                "shape": shape, **fast,
                "baseline": base,
                "tokens_per_s": tput, "baseline_tokens_per_s": base_tput,
                "tput_vs_baseline": ratio,
                "comm_vs_baseline": base["t_comm_s"] / fast["t_comm_s"],
            }
            out.append(rec)
            rows.append((
                f"moe_e2e/{regime}_{shape}", fast["t_step_s"] * 1e6,
                f"tput_vs_baseline={ratio:.2f}x;"
                f"comm_speedup={base['t_comm_s'] / fast['t_comm_s']:.2f}x;"
                f"dispatch={fast['dispatch_algo']};"
                f"combine={fast['combine_algo']};"
                f"S={fast['segments']}"))
    return out


# --------------------------------------------------------------------------
# telemetry leg: tracing overhead + a sample Perfetto artifact
# --------------------------------------------------------------------------

TRACE_OVERHEAD_TARGET = 0.02     # tracing on must cost < 2% wall clock


def _telemetry_pass() -> None:
    """The instrumented surface, deterministically: plan misses (planner
    spans), one cache hit, and residual recording (exec pricing +
    guideline checks) on a fresh service — the exact call paths whose
    tracing-on cost the <2% budget bounds."""
    machine = SyntheticTimingBackend(alpha_s=2e-6, beta_s_per_byte=2.5e-11,
                                     noise=0.03, seed=11)
    svc = PlannerService(quantum=16, params=CostParams.tpu_ici())
    for tokens in (4_096, 8_192, 16_384):
        for shape in ("uniform", "zipf"):
            n, S = ragged_moe_problem(P, tokens, shape)
            st = step_times(svc, machine, n, S)
            rec = svc.plan_record("alltoallv", S, row_bytes=ROW_BYTES)
            svc.record_execution("alltoallv", rec, st["t_dispatch_s"],
                                 row_bytes=ROW_BYTES, arg=S)


def trace_overhead_leg(rows: list, repeats: int = 8,
                       trace_path: str | None = None) -> dict:
    """Tracing-off vs tracing-on wall clock on the instrumented planning
    + residual paths, then one traced pass saved as a Chrome-trace
    artifact.  Asserts the <2% overhead budget.

    Methodology: one untimed warmup, then ``repeats`` INTERLEAVED
    off/on pairs with the min taken per mode — interleaving exposes both
    modes to the same slow machine drift (thermal, cache, co-tenants),
    and the min discards the stragglers.  A shared box's run-to-run
    noise still swamps a 2%-resolution wall-clock A/B, so the hard
    budget is asserted on the ACCOUNTED overhead — the per-span record
    cost (amortized over a tight loop, which is stable) times the spans
    one pass emits, relative to the pass time — while the A/B overhead
    is bounded against the pass's own observed noise band."""
    from repro.obs import trace as obs_trace

    prior = obs_trace.current()
    try:
        obs_trace.disable()
        _telemetry_pass()            # warmup: imports, first-call caches
        ts = {"off": [], "on": []}
        n_events = 0
        for _ in range(repeats):
            for mode in ("off", "on"):
                if mode == "on":
                    r = obs_trace.enable(obs_trace.TraceRecorder())
                else:
                    obs_trace.disable()
                t0 = time.perf_counter()
                _telemetry_pass()
                ts[mode].append(time.perf_counter() - t0)
                if mode == "on":
                    n_events = len(r.events)
        best = {mode: min(v) for mode, v in ts.items()}
        overhead = best["on"] / best["off"] - 1.0
        # accounted overhead: spans/pass x per-span cost / pass seconds
        obs_trace.disable()
        cal = obs_trace.TraceRecorder()
        reps = 20_000
        t0 = time.perf_counter()
        for _ in range(reps):
            cal.add_complete("cal/span", "planner", 0.0, 1e-6,
                             op="alltoallv", p=P, cost=1.2e-3, epoch=0,
                             row_bytes=ROW_BYTES, candidates=12)
        span_cost_s = (time.perf_counter() - t0) / reps
        accounted = span_cost_s * n_events / best["off"]
        assert accounted < TRACE_OVERHEAD_TARGET, (span_cost_s, n_events,
                                                   best, accounted)
        # the A/B must sit inside the budget once the box's own noise
        # band (spread of the UNTRACED passes) is granted
        noise = (max(ts["off"]) - best["off"]) / best["off"]
        assert overhead < TRACE_OVERHEAD_TARGET + noise, (best, ts,
                                                          overhead)
        # sample artifact: one traced pass, exported for Perfetto
        recorder = obs_trace.enable(obs_trace.TraceRecorder())
        _telemetry_pass()
        if trace_path is None:
            trace_path = os.path.join(RESULTS, "moe_e2e_trace.json")
        obs_trace.disable()
        saved = recorder.save(trace_path)
        with open(saved) as f:       # round-trip: valid Chrome-trace JSON
            doc = json.load(f)
        events = doc["traceEvents"]
        assert events and all("ph" in e and "ts" in e for e in events)
    finally:
        obs_trace.disable()
        if prior is not None:
            obs_trace.enable(prior)
    rows.append(("moe_e2e/trace_overhead", overhead * 1e6,
                 f"overhead_pct={overhead * 100:.3f};"
                 f"accounted_pct={accounted * 100:.4f};"
                 f"span_cost_us={span_cost_s * 1e6:.2f};"
                 f"best_off_s={best['off']:.4f};"
                 f"best_on_s={best['on']:.4f};"
                 f"events={len(events)};target_pct=2"))
    return {"path": saved, "events": len(events),
            "overhead_frac": overhead, "accounted_frac": accounted,
            "span_cost_s": span_cost_s, "best_off_s": best["off"],
            "best_on_s": best["on"], "repeats": repeats,
            "target_frac": TRACE_OVERHEAD_TARGET}


# --------------------------------------------------------------------------
# numeric end-to-end leg: a fwd+bwd step really flows through the plans
# --------------------------------------------------------------------------

def numeric_e2e(seed: int = 0, p: int = 8, d: int = 16) -> dict:
    """Route a ragged top-2 batch fwd+bwd through the service-selected
    plans in the NumPy oracles.  Outputs y, input gradients dX, and
    weight gradients dW must all match the dense per-token reference."""
    from repro.core.pipeline import (execute_alltoallv_plan_numpy,
                                     execute_reduce_scatterv_plan_numpy,
                                     execute_steps_numpy)

    rng = np.random.default_rng(seed)
    svc = PlannerService(quantum=1)
    n = rng.integers(8, 24, p)                    # ragged token counts
    offs = np.concatenate([[0], np.cumsum(n)])
    total = int(n.sum())
    x = [rng.standard_normal((int(n[i]), d)).astype(np.float32)
         for i in range(p)]
    dy = [rng.standard_normal((int(n[i]), d)).astype(np.float32)
          for i in range(p)]
    W = rng.standard_normal((p, d, d)).astype(np.float32)

    # top-2 routing: two DISTINCT experts + softmax gates per token — the
    # combine edge genuinely sums, so a pure-permutation fast path can't
    # fake it
    experts = [np.stack([rng.choice(p, 2, replace=False)
                         for _ in range(int(n[i]))]) for i in range(p)]
    gates = []
    for i in range(p):
        g = np.exp(rng.standard_normal((int(n[i]), 2)).astype(np.float32))
        gates.append(g / g.sum(axis=1, keepdims=True))

    # (token, slot) assignments per (source shard, expert), token order
    assign = [[[(t, s) for t in range(int(n[i])) for s in range(2)
                if experts[i][t, s] == j] for j in range(p)]
              for i in range(p)]
    S = np.array([[len(assign[i][j]) for j in range(p)] for i in range(p)],
                 np.int64)

    # ---- forward: dispatch alltoallv, expert matmul, combine rs(n) ----
    blocks = [[x[i][[t for t, _ in assign[i][j]]] for j in range(p)]
              for i in range(p)]
    disp = svc.plan_record("alltoallv", S, row_bytes=d * 4)
    received = execute_alltoallv_plan_numpy(disp.plan, blocks)
    y = [received[j] @ W[j] for j in range(p)]

    # expert j's received rows, in order = concat_i assign[i][j]
    meta = [[(i, t, s) for i in range(p) for (t, s) in assign[i][j]]
            for j in range(p)]
    gate_col = [np.array([gates[i][t, s] for i, t, s in meta[j]],
                         np.float32) for j in range(p)]

    # each expert's gated contribution over the FLAT token space; the
    # combine reduce_scatterv sums the top-2 partial outputs per token
    # and lands segment i on its source shard
    C = [np.zeros((total, d), np.float32) for _ in range(p)]
    for j in range(p):
        for k, (i, t, _s) in enumerate(meta[j]):
            C[j][offs[i] + t] += gate_col[j][k] * y[j][k]
    sizes = [int(v) for v in n]
    comb = svc.plan_record("reduce_scatterv", sizes, row_bytes=d * 4)
    got_y = execute_reduce_scatterv_plan_numpy(comb.plan, C)

    # ---- backward: allgatherv(dy), dX via rs(n), local dW ----
    agrad = svc.plan_record("allgatherv", sizes, row_bytes=d * 4)
    agp = agrad.plan
    bufs = np.zeros((p, agp.buf_rows, d), np.float32)
    for i in range(p):
        bufs[i, agp.in_starts[i]: agp.in_starts[i] + int(n[i])] = dy[i]
    dy_full = execute_steps_numpy(agp.steps, bufs)[:, :agp.total]
    # quantum=1: plan offsets == true offsets, so token (i, t)'s output
    # gradient sits at flat row offs[i] + t on every device
    dy_rows = [np.stack([dy_full[j][offs[i] + t] for i, t, _s in meta[j]])
               if meta[j] else np.zeros((0, d), np.float32)
               for j in range(p)]

    D = [np.zeros((total, d), np.float32) for _ in range(p)]
    for j in range(p):
        dxj = dy_rows[j] @ W[j].T                  # d(x_row) per assignment
        for k, (i, t, _s) in enumerate(meta[j]):
            D[j][offs[i] + t] += gate_col[j][k] * dxj[k]
    got_dx = execute_reduce_scatterv_plan_numpy(comb.plan, D)

    got_dw = [received[j].T @ (gate_col[j][:, None] * dy_rows[j])
              if meta[j] else np.zeros((d, d), np.float32)
              for j in range(p)]

    # ---- dense per-token reference ----
    max_err = 0.0
    want_dw = [np.zeros((d, d), np.float32) for _ in range(p)]
    for i in range(p):
        want_y = np.zeros((int(n[i]), d), np.float32)
        want_dx = np.zeros((int(n[i]), d), np.float32)
        for t in range(int(n[i])):
            for s in range(2):
                j, g = int(experts[i][t, s]), gates[i][t, s]
                want_y[t] += g * (x[i][t] @ W[j])
                want_dx[t] += g * (dy[i][t] @ W[j].T)
                want_dw[j] += g * np.outer(x[i][t], dy[i][t])
        max_err = max(max_err, float(np.abs(got_y[i] - want_y).max()),
                      float(np.abs(got_dx[i] - want_dx).max()))
    for j in range(p):
        max_err = max(max_err, float(np.abs(got_dw[j] - want_dw[j]).max()))
    assert max_err < 1e-4, max_err
    return {"p": p, "tokens": total, "d_model": d, "top_k": 2,
            "dispatch_algo": disp.algo, "combine_algo": comb.algo,
            "grad_gather_algo": agrad.algo, "max_abs_err": max_err}


def run(emit_rows: bool = True, out_path: str | None = None):
    assumed = CostParams.tpu_ici()
    machine = SyntheticTimingBackend(alpha_s=2e-6, beta_s_per_byte=2.5e-11,
                                     noise=0.03, seed=11)
    # quantum=16 keeps decode-sized blocks (16 rows/pair) exact; the
    # regular padded baseline needs no quantization, so a coarse quantum
    # would charge the fast path a pure bucketing tax here
    svc = PlannerService(quantum=16, params=assumed)
    rows: list = []
    regimes = throughput_study(svc, machine, rows)
    uniform = [r for r in regimes if r["shape"] == "uniform"]
    skewed = [r for r in regimes if r["shape"] != "uniform"]
    uniform_ok = all(r["tput_vs_baseline"] >= UNIFORM_TARGET
                     for r in uniform)
    skewed_win = all(r["tput_vs_baseline"] > 1.0 for r in skewed)
    assert uniform_ok, [
        (r["regime"], r["tput_vs_baseline"]) for r in uniform]
    assert skewed_win, [
        (r["regime"], r["tput_vs_baseline"]) for r in skewed]
    numeric = numeric_e2e()
    rows.append(("moe_e2e/numeric_leg", numeric["max_abs_err"],
                 f"dispatch={numeric['dispatch_algo']};"
                 f"combine={numeric['combine_algo']};"
                 f"top_k={numeric['top_k']};fwd_bwd_exact=True"))
    trace_info = trace_overhead_leg(rows)
    selected = sorted({a for r in regimes
                       for a in (r["dispatch_algo"], r["combine_algo"],
                                 r["grad_gather_algo"])})
    planner = {"plan_hits": svc.plan_hits, "plan_misses": svc.plan_misses,
               "params_epoch": svc.stats["params_epoch"],
               "drift_refits": svc.stats["drift_refits"],
               "selected": selected}
    payload = {
        "version": 3,              # v3: telemetry (planner counters + trace)
        "assumed_params": {"alpha": assumed.alpha, "beta": assumed.beta,
                           "time_unit": assumed.time_unit,
                           "data_unit": assumed.data_unit},
        "true_machine": {"alpha_s": machine.alpha_s,
                         "beta_s_per_byte": machine.beta_s_per_byte,
                         "noise": machine.noise,
                         "backend": machine.fingerprint()},
        "config": {"p": P, "d_model": D_MODEL, "d_ff": D_FF,
                   "row_bytes": ROW_BYTES, "peak_flops": PEAK_FLOPS,
                   "hbm_bw": HBM_BW, "train_step": "fwd+bwd"},
        "regimes": regimes,
        "numeric_e2e": numeric,
        "planner": planner,
        "trace": trace_info,
        "targets": {"uniform_ratio_target": UNIFORM_TARGET,
                    "uniform_ok": uniform_ok, "skewed_win": skewed_win},
    }
    if out_path is None:
        out_path = os.path.join(RESULTS, "moe_e2e.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    if emit_rows:
        emit(rows)
        print(f"# wrote {out_path}", file=sys.stderr)
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="JSON output path (default results/moe_e2e.json)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(out_path=args.out)


if __name__ == "__main__":
    main()
