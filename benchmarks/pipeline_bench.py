"""Monolithic-vs-pipelined crossover for the segmented slab dataplane.

For a grid of message scales, lower the same TUW schedule monolithically
(S=1) and pipelined (S in {2, 4, 8}) and compare

* **predicted** time — the tuner's own stage-synchronous plan cost
  (``plan_pipeline_cost``, which reduces to ``plan_step_cost`` at S=1)
  under the ASSUMED machine parameters (``CostParams.tpu_ici``), and
* **measured** time — the same candidates executed on a deterministic
  synthetic machine with DIFFERENT true parameters plus seeded noise
  (``SyntheticTimingBackend.measure``, the repo's device-free measurement
  methodology — see ``benchmarks/tuner_bench.py --synthetic``).

The interesting output is the CROSSOVER: the smallest per-block size at
which some S > 1 beats the monolithic plan.  Theory says it exists for
allgatherv (the broadcast phase repeats the full buffer every round, so
pipelining collapses d·β·M toward β·M) and the bench asserts that the
predicted and measured crossovers land on the same or adjacent grid
points — i.e. the cost model is sharp enough for the tuner to pick S.
For gatherv the payload-doubling rounds already sum to ~β·M, so
pipelining rarely wins; the bench reports that honestly instead of
asserting a win.

A final section runs a large-message signature through ``PlannerService``
and asserts the service selects a pipelined plan (S > 1) for it, and the
``alltoallv_moe`` section sweeps the zipf MoE dispatch signature,
asserting the fast-path properties: the tuner selects an S > 1 alltoallv
plan (per-tree segmentation made the stages real), payload-binned waves
cut ``padding_overhead`` on the skewed matrix, and pipelined plans stay
byte-identical to monolithic ones.

Writes ``results/pipeline_bench.json`` (schema: EXPERIMENTS.md §Pipeline
bench):

    PYTHONPATH=src python benchmarks/pipeline_bench.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):  # direct-script execution
    _REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_REPO, os.path.join(_REPO, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)
    from benchmarks.common import emit, moe_dispatch_matrix
else:
    from .common import emit, moe_dispatch_matrix

from repro.core.costmodel import CostParams
from repro.tuner import (PlannerService, SyntheticTimingBackend,
                         enumerate_candidates)

RESULTS = os.path.join(os.environ.get("REPRO_RESULTS", os.getcwd()),
                       "results")

P = 16                       # ranks
ROW_BYTES = 4                # float32, F=1: sizes are in rows
SEGMENTS = (1, 2, 4, 8)
SCALES = (16, 256, 4_096, 65_536, 1_048_576)   # rows per block


def _params_json(p: CostParams) -> dict:
    return {"alpha": p.alpha, "beta": p.beta,
            "time_unit": p.time_unit, "data_unit": p.data_unit}


def _candidates(op: str, rows_per_block: int, params: CostParams):
    """The S-family for one problem: monolithic b=1 plus pipelined
    variants, named by S."""
    m = [rows_per_block] * P
    arg = m if op != "alltoallv" else [[rows_per_block] * P] * P
    root = 0 if op in ("gatherv", "scatterv") else None
    cands = enumerate_candidates(op, arg, root, params, view="dataplane",
                                 buckets=(1,), segments=SEGMENTS)
    fam = {}
    for c in cands:
        if c.name in ("tuw(b=1)", "tuw_composed(b=1)"):
            fam[1] = c
        elif c.segments > 1:
            fam[c.segments] = c
    assert set(fam) == set(SEGMENTS), sorted(fam)
    return fam


def _crossover(rows_by_scale: dict[int, dict[int, float]]) -> int | None:
    """Smallest scale where some pipelined S beats S=1."""
    for scale in sorted(rows_by_scale):
        t = rows_by_scale[scale]
        if min(t[s] for s in t if s != 1) < t[1]:
            return scale
    return None


def sweep_op(op: str, assumed: CostParams, machine: SyntheticTimingBackend,
             rows: list) -> dict:
    sel_params = CostParams(assumed.alpha, assumed.beta * ROW_BYTES,
                            assumed.time_unit, "row")
    predicted: dict[int, dict[int, float]] = {}
    measured: dict[int, dict[int, float]] = {}
    scales = []
    for scale in SCALES:
        fam = _candidates(op, scale, sel_params)
        predicted[scale] = {s: c.cost(sel_params) for s, c in fam.items()}
        measured[scale] = {s: machine.measure(c, row_bytes=ROW_BYTES)
                           for s, c in fam.items()}
        best_pred = min(predicted[scale], key=lambda s: predicted[scale][s])
        best_meas = min(measured[scale], key=lambda s: measured[scale][s])
        scales.append({
            "rows_per_block": scale,
            "total_bytes": scale * P * ROW_BYTES,
            "predicted_s": {str(s): predicted[scale][s] for s in SEGMENTS},
            "measured_s": {str(s): measured[scale][s] for s in SEGMENTS},
            "best_S_predicted": best_pred,
            "best_S_measured": best_meas,
        })
        rows.append((
            f"pipeline/{op}/rows={scale}",
            measured[scale][best_meas] * 1e6,
            f"best_S_meas={best_meas};best_S_pred={best_pred};"
            f"mono_over_best="
            f"{measured[scale][1] / measured[scale][best_meas]:.2f}x"))
    xp, xm = _crossover(predicted), _crossover(measured)
    win = None
    if xm is not None:
        t = measured[xm]
        win = t[1] / min(t[s] for s in t if s != 1)
    return {"op": op, "p": P, "row_bytes": ROW_BYTES,
            "segments": list(SEGMENTS), "scales": scales,
            "crossover_rows_predicted": xp, "crossover_rows_measured": xm,
            "pipelined_win_at_measured_crossover": win}


def tuner_section(rows: list) -> dict:
    """PlannerService must pick S > 1 for the large-message signature and
    S = 1 for the tiny one — the pipeline knob is a *selection*, not a
    flag the caller has to know about."""
    svc = PlannerService(quantum=128)
    tiny = svc.plan_record("allgatherv", [64] * P, row_bytes=ROW_BYTES)
    big = svc.plan_record("allgatherv", [SCALES[-1]] * P,
                          row_bytes=ROW_BYTES)
    assert tiny.plan.segments == 1, tiny.algo
    assert big.plan.segments > 1, big.algo
    rows.append(("pipeline/tuner_selected_big", float(big.plan.segments),
                 f"algo={big.algo};tiny_algo={tiny.algo}"))
    return {"signature_rows_per_block": SCALES[-1],
            "selected": big.algo, "segments": big.plan.segments,
            "tiny_selected": tiny.algo, "tiny_segments": tiny.plan.segments}


def alltoallv_moe_section(assumed: CostParams,
                          machine: SyntheticTimingBackend,
                          rows: list) -> dict:
    """The MoE fast path: per-tree-segmented, payload-binned alltoallv.

    Sweeps token scales of the zipf dispatch signature (d_model=2048
    bf16 rows) and reports, per scale, the best monolithic plan vs the
    best pipelined (S > 1) plan under both the tuner's predicted cost
    and the synthetic machine.  Asserts the tentpole properties:

    * the service SELECTS an S > 1 alltoallv plan on at least one
      MoE-shaped signature (per-tree segmentation made the stages real);
    * the selected binned plan's ``padding_overhead`` is measurably below
      the unbinned single-bin waves on the skewed matrix;
    * pipelined and monolithic plans of the same schedule move byte-
      identical exact payloads.
    """
    import numpy as np

    from repro.core.jax_collectives import plan_alltoallv

    row_bytes = 2_048 * 2           # bf16 activations, d_model=2048
    sel_params = CostParams(assumed.alpha, assumed.beta * row_bytes,
                            assumed.time_unit, "row")
    svc = PlannerService(quantum=16)
    scales = []
    s_selected = None
    for tokens in (1_024, 16_384, 262_144):
        S_mat = moe_dispatch_matrix(P, tokens, "zipf")
        cands = enumerate_candidates("alltoallv", S_mat, None, sel_params,
                                     view="dataplane", buckets=(1, 2, 4),
                                     segments=SEGMENTS, wave_bins=(2.0,))
        pred = {c.name: c.cost(sel_params) for c in cands}
        meas = {c.name: machine.measure(c, row_bytes=row_bytes)
                for c in cands}
        best_pred = min(pred, key=pred.get)
        best_meas = min(meas, key=meas.get)
        mono_meas = min(v for k, v in meas.items() if "S=" not in k)
        pipe_meas = min(v for k, v in meas.items() if "S=" in k)
        rec = svc.plan_record("alltoallv", S_mat, row_bytes=row_bytes)
        if rec.plan.segments > 1:
            s_selected = rec.algo
        scales.append({
            "tokens": tokens,
            "best_predicted": best_pred,
            "best_measured": best_meas,
            "selected": rec.algo,
            "selected_segments": rec.plan.segments,
            "mono_over_pipe_measured": mono_meas / pipe_meas,
            "padding_overhead_selected": rec.plan.padding_overhead,
        })
        rows.append((
            f"pipeline/alltoallv_moe/tokens={tokens}",
            meas[best_meas] * 1e6,
            f"selected={rec.algo};best_meas={best_meas};"
            f"mono_over_pipe={mono_meas / pipe_meas:.2f}x"))
    assert s_selected is not None, (
        "per-tree segmentation must make the tuner select S > 1 on some "
        f"MoE-shaped alltoallv signature: {[s['selected'] for s in scales]}")
    # padding: binned waves vs single-bin waves on the largest skewed matrix
    S_mat = moe_dispatch_matrix(P, 262_144, "zipf")
    unbinned = plan_alltoallv(S_mat)
    binned = plan_alltoallv(S_mat, wave_bin_ratio=2.0)
    assert binned.padding_overhead < 0.5 * unbinned.padding_overhead, (
        unbinned.padding_overhead, binned.padding_overhead)
    # byte identity: pipelining re-times, never changes exact payloads
    byte_identity = all(
        plan_alltoallv(S_mat, segments=s).tree_bytes_exact
        == unbinned.tree_bytes_exact for s in SEGMENTS)
    assert byte_identity
    rows.append(("pipeline/alltoallv_moe/padding_overhead",
                 binned.padding_overhead,
                 f"unbinned={unbinned.padding_overhead:.3f};"
                 f"binned={binned.padding_overhead:.3f};"
                 f"byte_identity={byte_identity}"))
    return {"p": P, "row_bytes": row_bytes, "scales": scales,
            "s_gt1_selected": s_selected,
            "padding_overhead_unbinned": unbinned.padding_overhead,
            "padding_overhead_binned": binned.padding_overhead,
            "byte_identity": byte_identity}


def run(emit_rows: bool = True, out_path: str | None = None):
    assumed = CostParams.tpu_ici()
    # a deliberately mis-guessed true machine: slower startup, less BW
    machine = SyntheticTimingBackend(alpha_s=2e-6, beta_s_per_byte=2.5e-11,
                                     noise=0.03, seed=7)
    rows: list = []
    ops = [sweep_op(op, assumed, machine, rows)
           for op in ("allgatherv", "gatherv")]
    ag = ops[0]
    assert ag["crossover_rows_measured"] is not None, (
        "pipelining must win somewhere on the allgatherv grid")
    assert ag["crossover_rows_predicted"] is not None
    grid = sorted(SCALES)
    ip = grid.index(ag["crossover_rows_predicted"])
    im = grid.index(ag["crossover_rows_measured"])
    assert abs(ip - im) <= 1, (
        f"predicted crossover {ag['crossover_rows_predicted']} vs measured "
        f"{ag['crossover_rows_measured']}: more than one grid point apart")
    tuner = tuner_section(rows)
    moe = alltoallv_moe_section(assumed, machine, rows)
    payload = {
        "version": 2,
        "assumed_params": _params_json(assumed),
        "true_machine": {"alpha_s": machine.alpha_s,
                         "beta_s_per_byte": machine.beta_s_per_byte,
                         "noise": machine.noise,
                         "backend": machine.fingerprint()},
        "ops": ops,
        "tuner": tuner,
        "alltoallv_moe": moe,
    }
    if out_path is None:
        out_path = os.path.join(RESULTS, "pipeline_bench.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    if emit_rows:
        emit(rows)
        print(f"# wrote {out_path}", file=sys.stderr)
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="JSON output path "
                         "(default results/pipeline_bench.json)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(out_path=args.out)


if __name__ == "__main__":
    main()
