"""§Roofline: derive the three terms per (arch x shape x mesh) cell from
the dry-run artifacts (results/dryrun/*.json).

  compute    = HLO dot FLOPs / (chips * 197 TF/s bf16)
  memory     = HBM traffic proxy / (chips * 819 GB/s)
  collective = collective bytes / (chips * 50 GB/s link)

All three inputs are already per-device (SPMD program), so the chip count
divides out; chips only matter for the MODEL_FLOPS/HLO_FLOPs ratio, where
MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per optimizer step and
2*N*D for serving steps.  Writes results/roofline.json + prints the table.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, active_param_count, get_config

from .common import emit

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

RESULTS = os.path.join(os.environ.get("REPRO_RESULTS", os.getcwd()),
                       "results")


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = active_param_count(cfg)
    n_unembed = cfg.vocab * cfg.d_model
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        # last-token-only logits: the unembed runs once per sequence
        return 2.0 * ((n - n_unembed) * tokens
                      + n_unembed * shape.global_batch)
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def analyze_cell(rec: dict) -> dict | None:
    if not rec.get("ok") or "flow" not in rec:
        return None
    chips = 1
    for v in rec["mesh_shape"].values():
        chips *= v
    flow = rec["flow"]
    comp = flow["dot_flops"] / PEAK_FLOPS
    mem = flow.get("traffic_bytes_nocopy", flow["traffic_bytes"]) / HBM_BW
    coll = flow["total_collective_bytes"] / LINK_BW
    dom = max(("compute", comp), ("memory", mem), ("collective", coll),
              key=lambda kv: kv[1])
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = flow["dot_flops"] * chips
    bound = comp + mem + coll  # serial upper bound; overlap improves
    frac = comp / max(bound, 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec.get("variant", "baseline"),
        "chips": chips,
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dom[0], "dominant_s": dom[1],
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": mf / max(hlo_global, 1e-30),
        "roofline_fraction": frac,
        "peak_gb": rec["memory"]["peak_per_device_bytes"] / 1e9,
        "note": _note(dom[0], rec),
    }


def _note(dom: str, rec: dict) -> str:
    if dom == "collective":
        return ("reduce re-gathered param/activation bytes: fewer microbatch"
                " re-gathers, TP-stationary weights, or TUW-style size-aware"
                " schedules")
    if dom == "memory":
        return ("raise arithmetic intensity: larger per-device batch/tiles,"
                " fuse elementwise chains, bf16 caches")
    return "compute-bound: good; next lever is MXU utilization (tiling)"


def load_all() -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "dryrun", "*.json"))):
        rec = json.load(open(f))
        cell = analyze_cell(rec)
        if cell:
            out.append(cell)
    return out


def run(emit_rows=True):
    cells = load_all()
    rows = []
    for c in cells:
        vtag = "" if c["variant"] == "baseline" else f"/{c['variant']}"
        rows.append((
            f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}{vtag}",
            c["dominant_s"] * 1e6,
            f"dom={c['dominant']};comp={c['compute_s']:.3g}s;"
            f"mem={c['memory_s']:.3g}s;coll={c['collective_s']:.3g}s;"
            f"useful={c['useful_ratio']:.2f};frac={c['roofline_fraction']:.2f}"))
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "roofline.json"), "w") as f:
        json.dump(cells, f, indent=1)
    if emit_rows:
        emit(rows)
    return rows, cells
