"""Shared benchmark plumbing: the algorithm zoo under the alpha-beta model
(paper calibration: QDR InfiniBand, units = MPI_INT as in the tables) and
the CSV emitter (`name,us_per_call,derived`)."""
from __future__ import annotations

import sys

from repro.core import CostParams, allreduce_time, baselines, \
    build_gather_tree, simulate_gather
from repro.core import extensions as ext
from repro.core.distributions import NAMES, block_sizes
from repro.core.guidelines import regular_gather_time

# Calibrated so TUW_Gatherv magnitudes land near the paper's Tables 1-6
# (DESIGN.md §9): alpha ~ 1.8us startup, beta ~ 1.4ns per 4-byte int.
PARAMS = CostParams.infiniband_qdr()

SIZES_B = (1, 10, 100, 1_000, 10_000)


def gatherv_times(m, root, params=PARAMS):
    """All gatherv algorithms on one problem.  Times in us."""
    out = {}
    tuw = build_gather_tree(m, root=root)
    out["tuw"] = ext.simulate_gather_overlapped_construction(tuw, params)
    out["tuw_serial"] = simulate_gather(tuw, params,
                                        include_construction=True)
    out["linear"] = simulate_gather(baselines.linear_tree(m, root), params)
    out["binomial"] = simulate_gather(baselines.binomial_tree(m, root),
                                      params)
    out["knomial3"] = simulate_gather(baselines.knomial_tree(m, root, 3),
                                      params)
    # the Intel-MPI library flavor (linear intra + binomial leaders): the
    # paper's Tables 7-11 baseline, NOT this repo's TUW-in-TUW two_level
    out["two_level"] = simulate_gather(
        baselines.two_level_library_tree(m, root, 16), params)
    return out


def gather_regular(p, per_block, root, params=PARAMS):
    """MPI_Gather analog: binomial tree on equal blocks."""
    return regular_gather_time(p, per_block, root, params)


def guideline2_rhs(m, root, params=PARAMS):
    return (allreduce_time(len(m), 1, params)
            + regular_gather_time(len(m), max(m), root, params))


def emit(rows, file=sys.stdout):
    """CSV per harness contract: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}", file=file)


def moe_load_fractions(p: int, shape: str, seed: int = 0):
    """The canonical MoE expert-load shapes used by the fast-path bench,
    the e2e bench, and the tests — ONE definition so they all validate
    the same matrices.  ``uniform``: balanced; ``single_hot``: one expert
    takes half the traffic; ``zipf``: loads ~ 1/rank^1.2, shuffled."""
    import numpy as np

    rng = np.random.default_rng(seed)
    if shape == "uniform":
        return np.full(p, 1.0 / p)
    if shape == "single_hot":
        frac = np.full(p, 0.5 / (p - 1))
        frac[min(3, p - 1)] = 0.5
        return frac
    if shape == "zipf":
        w = 1.0 / np.arange(1, p + 1) ** 1.2
        return rng.permutation(w / w.sum())
    raise ValueError(shape)


def moe_dispatch_matrix(p: int, tokens: int, shape: str,
                        seed: int = 0):
    """S[i][j]: token rows shard ``i`` sends to expert ``j`` — each
    expert's load split as evenly as possible over the p source shards
    (every expert serves at least one token)."""
    import numpy as np

    S = np.zeros((p, p), np.int64)
    for j, f in enumerate(moe_load_fractions(p, shape, seed)):
        tj = max(1, int(f * tokens))
        base, rem = divmod(tj, p)
        S[:, j] = base
        S[:rem, j] += 1
    return S


def ragged_moe_problem(p: int, tokens: int, shape: str, seed: int = 0):
    """(n, S) for the fwd+bwd bench: ``n[i]`` ragged per-shard token
    counts (the same canonical load shape applied to the data-parallel
    axis — real batches are ragged after packing/filtering) and
    ``S[i][j]`` shard ``i``'s rows for expert ``j`` (largest-remainder
    split of ``n[i]`` over the expert-load fractions, so every row sums
    back to ``n[i]``).  ``uniform`` stays fully balanced on both axes."""
    import numpy as np

    ef = moe_load_fractions(p, shape, seed)
    sf = moe_load_fractions(p, shape, seed + 1)  # decorrelated raggedness
    n = np.maximum(1, (sf * tokens).astype(np.int64))
    S = np.zeros((p, p), np.int64)
    for i in range(p):
        row = np.floor(ef * n[i]).astype(np.int64)
        order = np.argsort(-(ef * n[i] - row))
        row[order[: int(n[i] - row.sum())]] += 1
        S[i] = row
    return n, S
