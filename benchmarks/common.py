"""Shared benchmark plumbing: the algorithm zoo under the alpha-beta model
(paper calibration: QDR InfiniBand, units = MPI_INT as in the tables) and
the CSV emitter (`name,us_per_call,derived`)."""
from __future__ import annotations

import sys

from repro.core import CostParams, allreduce_time, baselines, \
    build_gather_tree, simulate_gather
from repro.core import extensions as ext
from repro.core.distributions import NAMES, block_sizes
from repro.core.guidelines import regular_gather_time

# Calibrated so TUW_Gatherv magnitudes land near the paper's Tables 1-6
# (DESIGN.md §9): alpha ~ 1.8us startup, beta ~ 1.4ns per 4-byte int.
PARAMS = CostParams.infiniband_qdr()

SIZES_B = (1, 10, 100, 1_000, 10_000)


def gatherv_times(m, root, params=PARAMS):
    """All gatherv algorithms on one problem.  Times in us."""
    out = {}
    tuw = build_gather_tree(m, root=root)
    out["tuw"] = ext.simulate_gather_overlapped_construction(tuw, params)
    out["tuw_serial"] = simulate_gather(tuw, params,
                                        include_construction=True)
    out["linear"] = simulate_gather(baselines.linear_tree(m, root), params)
    out["binomial"] = simulate_gather(baselines.binomial_tree(m, root),
                                      params)
    out["knomial3"] = simulate_gather(baselines.knomial_tree(m, root, 3),
                                      params)
    # the Intel-MPI library flavor (linear intra + binomial leaders): the
    # paper's Tables 7-11 baseline, NOT this repo's TUW-in-TUW two_level
    out["two_level"] = simulate_gather(
        baselines.two_level_library_tree(m, root, 16), params)
    return out


def gather_regular(p, per_block, root, params=PARAMS):
    """MPI_Gather analog: binomial tree on equal blocks."""
    return regular_gather_time(p, per_block, root, params)


def guideline2_rhs(m, root, params=PARAMS):
    return (allreduce_time(len(m), 1, params)
            + regular_gather_time(len(m), max(m), root, params))


def emit(rows, file=sys.stdout):
    """CSV per harness contract: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}", file=file)


def moe_load_fractions(p: int, shape: str, seed: int = 0):
    """The canonical MoE expert-load shapes used by the fast-path bench,
    the e2e bench, and the tests — ONE definition so they all validate
    the same matrices.  ``uniform``: balanced; ``single_hot``: one expert
    takes half the traffic; ``zipf``: loads ~ 1/rank^1.2, shuffled."""
    import numpy as np

    rng = np.random.default_rng(seed)
    if shape == "uniform":
        return np.full(p, 1.0 / p)
    if shape == "single_hot":
        frac = np.full(p, 0.5 / (p - 1))
        frac[min(3, p - 1)] = 0.5
        return frac
    if shape == "zipf":
        w = 1.0 / np.arange(1, p + 1) ** 1.2
        return rng.permutation(w / w.sum())
    raise ValueError(shape)


def moe_dispatch_matrix(p: int, tokens: int, shape: str,
                        seed: int = 0):
    """S[i][j]: token rows shard ``i`` sends to expert ``j`` — each
    expert's load split as evenly as possible over the p source shards
    (every expert serves at least one token)."""
    import numpy as np

    S = np.zeros((p, p), np.int64)
    for j, f in enumerate(moe_load_fractions(p, shape, seed)):
        tj = max(1, int(f * tokens))
        base, rem = divmod(tj, p)
        S[:, j] = base
        S[:rem, j] += 1
    return S


def serve_trace(p: int, steps: int, seed: int = 0, *, base_qps: float = 64.0,
                diurnal_amp: float = 0.8, period: int | None = None,
                max_batch: int = 256, mean_decode_len: int = 48,
                prompt_len_range: tuple[int, int] = (8, 512),
                top_k: int = 2, expert_drift: float = 0.02):
    """Deterministic serving trace: diurnal QPS + continuous batching +
    per-step top-k expert routing — ONE seeded generator shared by
    ``benchmarks/serve_bench.py``, the steady-state churn test
    (``tests/test_serving.py``), and ``examples/serve_lm.py``, so bench
    rows are reproducible run-to-run.

    Dynamics per decode step ``t``:

    * arrivals ~ Poisson(rate(t)) with a sinusoidal diurnal rate
      ``base_qps·(1 + diurnal_amp·sin(2πt/period))`` (one step = one
      scheduler tick); each arrival gets a ragged prompt length
      log-uniform in ``prompt_len_range`` and joins the active set,
      capped at ``max_batch`` (overflow waits in queue);
    * each active request finishes with probability
      ``1/mean_decode_len`` per step (geometric decode lengths);
    * every active request contributes ``top_k`` routed rows; expert
      popularity is a slowly rotating zipf (``expert_drift`` controls
      the rotation rate), so the load shape drifts the way diurnal
      production traffic does.

    Returns a list of ``steps`` dicts: ``step``, ``active`` (batch),
    ``arrivals``, ``queued``, ``prompt_lens`` (this step's admissions),
    ``n`` (per-shard routed row counts, shard = request slot mod p) and
    ``S`` (p×p dispatch matrix, ``S[i][j]`` = rows shard i sends expert
    j; ``sum(S) == top_k·active``).
    """
    import numpy as np

    rng = np.random.default_rng(seed)
    period = int(period or max(8, steps // 2))
    lo, hi = prompt_len_range
    active: list[int] = []       # per-request shard ids
    queued: list[int] = []
    zipf = 1.0 / np.arange(1, p + 1) ** 1.1
    order = rng.permutation(p)
    out = []
    slot = 0
    for t in range(int(steps)):
        rate = base_qps * (1.0 + diurnal_amp
                           * np.sin(2.0 * np.pi * t / period))
        arrivals = int(rng.poisson(max(0.0, rate)))
        plens = np.exp(rng.uniform(np.log(lo), np.log(hi + 1),
                                   arrivals)).astype(np.int64)
        for _ in range(arrivals):
            queued.append(slot % p)
            slot += 1
        # completions, then admissions up to the batch cap
        keep = rng.random(len(active)) >= 1.0 / mean_decode_len
        active = [s for s, k in zip(active, keep) if k]
        while queued and len(active) < max_batch:
            active.append(queued.pop(0))
        # slow expert-popularity drift: rotate the zipf assignment
        if expert_drift > 0 and rng.random() < expert_drift * p:
            order = np.roll(order, 1)
        w = zipf[np.argsort(order)]
        w = w / w.sum()
        S = np.zeros((p, p), np.int64)
        n = np.zeros(p, np.int64)
        if active:
            shards = np.asarray(active, np.int64)
            for _ in range(top_k):
                experts = rng.choice(p, size=len(active), p=w)
                np.add.at(S, (shards, experts), 1)
            n = S.sum(axis=1)
        out.append({"step": t, "active": len(active),
                    "arrivals": arrivals, "queued": len(queued),
                    "prompt_lens": plens, "n": n, "S": S})
    return out


def ragged_moe_problem(p: int, tokens: int, shape: str, seed: int = 0):
    """(n, S) for the fwd+bwd bench: ``n[i]`` ragged per-shard token
    counts (the same canonical load shape applied to the data-parallel
    axis — real batches are ragged after packing/filtering) and
    ``S[i][j]`` shard ``i``'s rows for expert ``j`` (largest-remainder
    split of ``n[i]`` over the expert-load fractions, so every row sums
    back to ``n[i]``).  ``uniform`` stays fully balanced on both axes."""
    import numpy as np

    ef = moe_load_fractions(p, shape, seed)
    sf = moe_load_fractions(p, shape, seed + 1)  # decorrelated raggedness
    n = np.maximum(1, (sf * tokens).astype(np.int64))
    S = np.zeros((p, p), np.int64)
    for i in range(p):
        row = np.floor(ef * n[i]).astype(np.int64)
        order = np.argsort(-(ef * n[i] - row))
        row[order[: int(n[i] - row.sum())]] += 1
        S[i] = row
    return n, S
