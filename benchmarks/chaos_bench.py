"""Chaos bench: the fault-aware runtime driven through scripted faults.

Three legs, all deterministic (seeded ``FaultSchedule``, NumPy oracle —
no devices needed):

* **degraded_link** — one host's links drop to 1/16 bandwidth.  A
  fault-oblivious ``PlannerService`` and a fault-aware one (same
  problem, ``update_link_health`` fed the ×16 factor) both plan the
  gatherv; the bench asserts the aware plan's tree demotes the sick
  rank to a STRUCTURAL leaf (no step delivers rows into it), beats the
  oblivious plan by >= 1.2x bottleneck span on the degraded machine
  (``pipeline.plan_host_times`` under the ``DegradedCostParams`` truth),
  and stays byte-identical to the oblivious plan's gathered result
  under the NumPy step oracle — routing around a fault never changes
  the answer.

* **host_loss** — a hard ``HostLoss`` at a chosen step: the elastic
  shrink path rebuilds gatherv / allgatherv / alltoallv /
  reduce_scatterv / allreducev over the surviving p-1 ranks
  (``shrink_sizes`` / ``shrink_matrix`` / ``remap_root``) and the bench
  asserts exact bytes and exact sums on the survivors.

* **timeout_retry** — scripted ``TimeoutFault`` events through the host
  drivers' deadline path (``call_with_deadline`` + fault hook): a
  transient fault is absorbed by bounded retry; a persistent one
  escalates to ``CollectiveTimeout`` and lands on the straggler ladder.

Writes ``results/chaos_bench.json`` (schema: EXPERIMENTS.md §Chaos
bench):

    PYTHONPATH=src python benchmarks/chaos_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

if __package__ in (None, ""):  # direct-script execution
    _REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_REPO, os.path.join(_REPO, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)
    from benchmarks.common import emit
else:
    from .common import emit

from repro.core import jax_collectives as jc
from repro.core.costmodel import CostParams, DegradedCostParams
from repro.core.pipeline import (execute_allreducev_plan_numpy,
                                 execute_alltoallv_plan_numpy,
                                 execute_reduce_scatterv_plan_numpy,
                                 execute_steps_numpy, plan_host_times)
from repro.runtime.chaos import (ExecutionFaultInjector, FaultSchedule,
                                 HostLoss, LinkDegrade, TimeoutFault,
                                 remap_root, shrink_matrix, shrink_sizes,
                                 surviving_ranks)
from repro.runtime.straggler import StragglerPolicy
from repro.tuner import PlannerService

RESULTS = os.path.join(os.environ.get("REPRO_RESULTS", os.getcwd()),
                       "results")

SCHEMA_VERSION = 1
VICTIM = 2
FACTOR = 16.0


def _receives_into(steps, rank: int) -> int:
    """Rows any step delivers INTO ``rank`` — 0 iff it is a structural
    leaf of the executed schedule (sends only)."""
    rows = 0
    for perm, _payload, _ss, _rs, recv_valid in steps:
        for _s, d in perm:
            if d == rank:
                rows += int(recv_valid[d])
    return rows


def _gather_oracle(plan, blocks, root: int, F: int):
    p = plan.p
    bufs = np.zeros((p, plan.buf_rows, F), np.int64)
    for i, b in enumerate(blocks):
        bufs[i, plan.offsets[i]: plan.offsets[i] + len(b)] = b
    out = execute_steps_numpy(plan.steps, bufs)
    return out[root, : plan.total]


def degraded_link_leg(quick: bool) -> tuple[list, dict]:
    """Replanning around a x16-degraded host: structure, speed, bytes."""
    p = 8 if quick else 16
    # the victim's neighbor holds a large block, so the oblivious
    # free-cube merge makes the victim an interior receiver; its own
    # block is large enough that forwarding it twice hurts
    rng = np.random.default_rng(7)
    m = [int(x) for x in rng.integers(8, 64, p)]
    m[VICTIM] = 4000
    m[VICTIM + 1] = 3000
    root = 0
    schedule = FaultSchedule.scripted(LinkDegrade(VICTIM, FACTOR, start=0))
    truth_base = CostParams.tpu_ici()
    truth = DegradedCostParams(truth_base, schedule.health_map(0))

    oblivious = PlannerService(quantum=1)
    aware = PlannerService(quantum=1)
    changed = aware.update_link_health(
        factors={VICTIM: FACTOR}, incident=("chaos", 0))
    assert changed and aware.params_epoch == 1
    rec_o = oblivious.plan_record("gatherv", m, root=root)
    rec_a = aware.plan_record("gatherv", m, root=root)

    # tree STRUCTURE: the aware plan never delivers rows into the victim
    rows_in_o = _receives_into(rec_o.plan.steps, VICTIM)
    rows_in_a = _receives_into(rec_a.plan.steps, VICTIM)
    assert rows_in_a == 0, \
        f"aware plan still routes {rows_in_a} rows into the victim"
    assert rows_in_o > 0, "oblivious plan never stressed the victim " \
        "(bench sizes need retuning)"

    # step time on the DEGRADED machine: bottleneck-rank busy span
    span_o = max(plan_host_times(rec_o.plan.steps, p, truth).values())
    span_a = max(plan_host_times(rec_a.plan.steps, p, truth).values())
    speedup = span_o / span_a
    assert speedup >= 1.2, \
        f"aware plan only {speedup:.2f}x over oblivious (need >= 1.2)"

    # byte identity: both plans gather the same rows, exactly
    F = 2
    blocks = [rng.integers(0, 1_000_000, (s, F)) for s in m]
    expect = np.concatenate(blocks, axis=0)
    got_o = _gather_oracle(rec_o.plan, blocks, root, F)
    got_a = _gather_oracle(rec_a.plan, blocks, root, F)
    np.testing.assert_array_equal(got_o, expect)
    np.testing.assert_array_equal(got_a, expect)

    rows = [
        (f"chaos/degraded_link_p{p}_oblivious", span_o * 1e6,
         f"algo={rec_o.algo};rows_into_victim={rows_in_o}"),
        (f"chaos/degraded_link_p{p}_aware", span_a * 1e6,
         f"algo={rec_a.algo};rows_into_victim={rows_in_a};"
         f"speedup={speedup:.2f}"),
    ]
    return rows, {
        "p": p, "victim": VICTIM, "factor": FACTOR, "root": root,
        "oblivious": {"algo": rec_o.algo, "span_s": span_o,
                      "rows_into_victim": rows_in_o},
        "aware": {"algo": rec_a.algo, "span_s": span_a,
                  "rows_into_victim": rows_in_a,
                  "params_epoch": aware.params_epoch,
                  "link_health": aware.stats["link_health"]},
        "speedup": speedup, "byte_identical": True,
    }


def host_loss_leg(quick: bool) -> tuple[list, dict]:
    """Hard loss at step 2: every collective rebuilt over the survivors
    with exact bytes / exact sums."""
    p = 6 if quick else 8
    loss_step = 2
    schedule = FaultSchedule.scripted(HostLoss(VICTIM, loss_step))
    rng = np.random.default_rng(11)
    sizes = [int(x) for x in rng.integers(1, 40, p)]
    root = 0
    assert not schedule.lost_hosts(loss_step - 1)
    survivors = surviving_ranks(p, schedule.lost_hosts(loss_step))
    assert len(survivors) == p - 1 and VICTIM not in survivors
    q = len(survivors)
    ssizes = shrink_sizes(sizes, survivors)
    sroot = remap_root(root, survivors)
    svc = PlannerService(quantum=1)
    F = 2
    blocks = [rng.integers(0, 1_000_000, (s, F)) for s in ssizes]
    expect = np.concatenate(blocks, axis=0)
    checked = []

    # gatherv: survivors' rows, exactly, at the remapped root
    plan = svc.plan("gatherv", ssizes, root=sroot)
    np.testing.assert_array_equal(
        _gather_oracle(plan, blocks, sroot, F), expect)
    checked.append("gatherv")

    # allgatherv: every survivor ends with all survivors' rows
    plan = svc.plan("allgatherv", ssizes)
    bufs = np.zeros((q, plan.buf_rows, F), np.int64)
    for i, b in enumerate(blocks):
        bufs[i, plan.in_starts[i]: plan.in_starts[i] + len(b)] = b
    out = execute_steps_numpy(plan.steps, bufs)
    for j in range(q):
        np.testing.assert_array_equal(out[j, : plan.total], expect)
    checked.append("allgatherv")

    # alltoallv: the shrunk matrix drops the dead rank's row AND column
    S = rng.integers(0, 20, (p, p))
    Sq = shrink_matrix(S, survivors)
    a2a = [[rng.integers(0, 1_000_000, (int(Sq[i][j]), F))
            for j in range(q)] for i in range(q)]
    plan = svc.plan("alltoallv", [list(map(int, r)) for r in Sq])
    got = execute_alltoallv_plan_numpy(plan, a2a)
    for j in range(q):
        exp = np.concatenate([a2a[i][j] for i in range(q)], axis=0) \
            if q else a2a[0][j]
        np.testing.assert_array_equal(got[j], exp)
    checked.append("alltoallv")

    # reduce_scatterv / allreducev: EXACT sums over the survivors only
    # (int64 contributions — associativity cannot blur the check)
    total = sum(ssizes)
    contribs = [rng.integers(-1000, 1000, (total, F)).astype(np.int64)
                for _ in range(q)]
    truth = np.sum(contribs, axis=0)
    plan = svc.plan("reduce_scatterv", ssizes)
    red = execute_reduce_scatterv_plan_numpy(plan, contribs)
    off = 0
    for j, s in enumerate(ssizes):
        np.testing.assert_array_equal(red[j], truth[off: off + s])
        off += s
    checked.append("reduce_scatterv")

    plan = svc.plan("allreducev", ssizes)
    allred = execute_allreducev_plan_numpy(plan, contribs)
    for j in range(q):
        np.testing.assert_array_equal(allred[j], truth)
    checked.append("allreducev")

    rows = [(f"chaos/host_loss_p{p}_to_{q}", 0.0,
             f"ops={len(checked)};survivors={q};exact=1")]
    return rows, {"p": p, "lost": VICTIM, "loss_step": loss_step,
                  "survivors": survivors, "root_remap": sroot,
                  "ops_exact": checked}


def timeout_retry_leg(quick: bool) -> tuple[list, dict]:
    """Deadline/retry path: transient faults absorbed, persistent ones
    escalate to CollectiveTimeout and climb the straggler ladder."""
    schedule = FaultSchedule.scripted(
        TimeoutFault(step=0, op="gatherv", attempts=1),   # transient
        TimeoutFault(step=1, op="gatherv", attempts=9))   # persistent
    policy = StragglerPolicy()
    inj = ExecutionFaultInjector(schedule).install()
    jc.configure_step_deadline(1.0, retries=2, backoff=2.0)
    try:
        out, _dt, attempts = jc.call_with_deadline("gatherv", lambda: 42)
        assert out == 42 and attempts == 2, (out, attempts)
        inj.advance(1)
        escalated = False
        try:
            jc.call_with_deadline("gatherv", lambda: 42)
        except jc.CollectiveTimeout:
            escalated = True
            action = policy.record_timeout(1)
        assert escalated, "persistent fault failed to escalate"
        assert action == "warn"
    finally:
        inj.uninstall()
        jc.configure_step_deadline(None)
    rows = [("chaos/timeout_retry", 0.0,
             f"injected={inj.injected};escalated=1;action={action}")]
    return rows, {"injected": inj.injected, "transient_attempts": 2,
                  "escalated": escalated, "ladder_action": action}


def run(quick: bool = False):
    rows: list = []
    payload: dict = {"version": SCHEMA_VERSION, "quick": bool(quick)}
    r, payload["degraded_link"] = degraded_link_leg(quick)
    rows += r
    r, payload["host_loss"] = host_loss_leg(quick)
    rows += r
    r, payload["timeout_retry"] = timeout_retry_leg(quick)
    rows += r
    return rows, payload


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller problems (CI chaos lane)")
    ap.add_argument("--out", default=os.path.join(RESULTS,
                                                  "chaos_bench.json"))
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    rows, payload = run(quick=args.quick)
    emit(rows)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
