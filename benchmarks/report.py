"""Render the §Roofline tables as markdown from results/dryrun/*.json:

    PYTHONPATH=src python -m benchmarks.report          # baselines
    PYTHONPATH=src python -m benchmarks.report --variants
"""
from __future__ import annotations

import argparse

from .roofline import load_all


def render(mesh: str, variants: bool) -> str:
    cells = [c for c in load_all() if c["mesh"] == mesh
             and (variants or c["variant"] == "baseline")]
    out = [f"### {mesh} ({'all variants' if variants else 'baseline'})",
           "",
           "| arch | shape | variant | compute s | memory s | collective s"
           " | dominant | useful | peak GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"],
                                          c["variant"])):
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['variant']} | "
            f"{c['compute_s']:.3g} | {c['memory_s']:.3g} | "
            f"{c['collective_s']:.3g} | {c['dominant']} | "
            f"{c['useful_ratio']:.2f} | {c['peak_gb']:.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variants", action="store_true")
    args = ap.parse_args()
    for mesh in ("single", "multipod"):
        print(render(mesh, args.variants))
        print()


if __name__ == "__main__":
    main()
